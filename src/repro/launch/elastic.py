"""Elastic preemption-tolerant ensemble training (DESIGN.md
§Elastic-training).

The paper's communication-free property makes chain↔device placement
pure scheduling metadata: a chain's Gibbs stream depends only on its own
shard, its own fold_in key lane, and its own state — never on WHERE it
runs or who its neighbours are.  This module cashes that in as
elasticity, the thing distributed-LDA systems pay synchronization
protocols for:

  * **dynamic placement** — `DevicePool` is a membership view (ordered
    device ids + an epoch bumped on every change) and
    `compute_placement` deterministically packs the M chains onto it in
    balanced contiguous groups.  Placement is recomputed at EM-round
    boundaries only, and it rides OUTSIDE the compiled round (the jit
    cache is keyed on `(bucket_signature, cfg, backend)` — no placement
    anywhere in it), so a repack after device loss causes ZERO retraces
    and survivors' streams are bit-identical to a run launched with the
    surviving layout from the start.

  * **per-chain logical progress** — each chain's round keys fold its
    OWN round counter (`ChainSupervisor._fold_keys` with an [M] round
    vector), so one compiled [M]-wide round can serve chains sitting at
    different logical rounds: a chain restored after device loss replays
    its round-s stream while survivors advance through round r.  The
    catch-up loop then freezes finished chains via a selective merge
    (`jnp.where` on an active mask) until every alive chain has run
    exactly R rounds — making the final ensemble bitwise-equal to an
    undisturbed run, device loss or not.

  * **round deadlines / stragglers** — per-device soft barriers on the
    chaos-suite `VirtualClock`: a device whose round exceeds
    `deadline_s` gets its chains flagged `F_STRAGGLER` (correct, merely
    late — flag only), `straggle_rounds` consecutive misses evict the
    device from the pool (its chains repack, state intact — slow is not
    dead), and `speculative_replace` optionally re-places the slowest
    device's chains onto the least-loaded on-time device at the first
    miss.

  * **async crash-consistent checkpointing** — `AsyncCheckpointManager`
    snapshots to host at the boundary and publishes in a background
    thread through the same atomic rename protocol; its bounded-
    staleness guarantee (a save is only accepted once the previous one
    is durable) means resume after preemption loses at most ONE EM
    round.  SIGTERM (or a deterministic "preempt" `ElasticEvent`) is
    latched by `PreemptionSignal` and honoured at the next boundary:
    flush, final synchronous save with the full host bookkeeping
    (per-chain progress/alive/epoch/restarts + wall round) in the
    manifest, exit resumable.

Fault semantics at the pool level (the chain-level taxonomy is
`core.supervisor`'s): a LOST device's chains restore from the last
durable checkpoint (no PRNG-epoch bump — the chain state was healthy,
the environment failed, and exact replay is what makes recovery exact);
with no checkpoint directory they are quarantined, which is exact for
the usual communication-free reason.  A SLOW device's chains are never
restored — they are correct, and moving them is free because state is
placement-invariant.
"""
from __future__ import annotations

import dataclasses
import signal as _signal

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointManager, CheckpointManager,
                              read_manifest, restore_chain,
                              restore_elastic, save_checkpoint)
from repro.core.supervisor import ChainSupervisor, F_KILLED, F_STRAGGLER
from repro.core.types import GibbsState, SLDAConfig, partition
from repro.core.plan import build_schedule
from repro.testing.faults import ElasticEvent, VirtualClock

# ----------------------------------------------------------- membership view


class DevicePool:
    """Ordered device membership + an epoch bumped on every change.
    The pool is a VIEW — it holds ids (ints or strings), not device
    handles; the compiled round never sees it."""

    def __init__(self, devices):
        if isinstance(devices, int):
            devices = list(range(devices))
        if not devices:
            raise ValueError("device pool cannot start empty")
        self._ids = list(devices)
        self.epoch = 0
        self.history = [("init", tuple(self._ids))]

    @property
    def ids(self):
        return tuple(self._ids)

    def __len__(self):
        return len(self._ids)

    def __contains__(self, dev):
        return dev in self._ids

    def lose(self, dev):
        if dev not in self._ids:
            return False
        if len(self._ids) == 1:
            raise RuntimeError(
                f"device {dev!r} is the last pool member — losing it "
                "leaves nowhere to run; treat as total failure upstream")
        self._ids.remove(dev)
        self.epoch += 1
        self.history.append(("lose", dev))
        return True

    def join(self, dev):
        if dev in self._ids:
            return False
        self._ids.append(dev)
        self.epoch += 1
        self.history.append(("join", dev))
        return True


def compute_placement(chain_ids, devices) -> dict:
    """Deterministic balanced placement: chains (sorted) split into
    len(devices) contiguous groups, earlier devices taking the +1
    remainders.  Pure function of (chain_ids, device order) — the same
    membership view always yields the same placement, which is what
    makes a repack reproducible from the event log alone."""
    devices = list(devices)
    if not devices:
        raise ValueError("cannot place chains on an empty pool")
    chains = sorted(int(c) for c in chain_ids)
    n, k = len(chains), len(devices)
    per, rem = divmod(n, k)
    out, i = {}, 0
    for j, dev in enumerate(devices):
        take = per + (1 if j < rem else 0)
        out[dev] = tuple(chains[i:i + take])
        i += take
    return out


# -------------------------------------------------------- preemption signal


class PreemptionSignal:
    """Latched preemption notice.  `install()` hooks SIGTERM (the
    cloud-preemption convention) so an external notice and a
    deterministic chaos `ElasticEvent("preempt", ...)` flow through the
    same flag; the runner honours it at the next round boundary."""

    def __init__(self):
        self.triggered = False
        self._prev = None

    def set(self, *_args):
        self.triggered = True

    def clear(self):
        self.triggered = False

    def install(self):
        self._prev = _signal.signal(_signal.SIGTERM, self.set)
        return self

    def uninstall(self):
        if self._prev is not None:
            _signal.signal(_signal.SIGTERM, self._prev)
            self._prev = None


# ------------------------------------------------------------- configuration


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Pool-level runtime policy (chain-level health/recovery stay in
    `HealthConfig`/`RecoveryPolicy`)."""

    round_iters: int = 2         # EM iters per round; must divide
                                 # cfg.n_iters — every round is the SAME
                                 # compiled computation, and a chain
                                 # replaying round s after restore must
                                 # replay the SAME round size it first ran
    async_ckpt: bool = True      # AsyncCheckpointManager vs synchronous
    ckpt_every: int = 1          # checkpoint every k wall rounds; the
                                 # bounded-staleness guarantee scales
                                 # with it — resume/recovery loses at
                                 # most `ckpt_every` EM rounds
    keep_checkpoints: int = 3
    catch_up: bool = True        # run extra wall rounds until every alive
                                 # chain reaches R logical rounds (exact
                                 # recovery); False = fixed wall budget,
                                 # laggards ship stale state (reported)
    device_round_s: float = 1.0  # simulated seconds one device takes per
                                 # round (the VirtualClock's unit of work)
    deadline_s: float | None = None   # round deadline; None disables the
                                      # straggler machinery entirely
    straggle_rounds: int = 2     # consecutive deadline misses before the
                                 # device is evicted from the pool
    speculative_replace: bool = False  # move the slowest device's chains
                                       # to the least-loaded on-time
                                       # device at the FIRST miss


@dataclasses.dataclass
class ElasticReport:
    """What an elastic run observed — the supervisor report's pool-level
    twin.  `alive`/`status`/`restarts` as in `SupervisorReport`;
    `progress` is each chain's completed logical rounds (== R everywhere
    on a clean or fully-caught-up run)."""

    alive: np.ndarray
    status: np.ndarray
    restarts: np.ndarray
    progress: np.ndarray
    wall_rounds: int
    logical_rounds: int
    history: list
    pool_history: list
    placements: list
    preempted: bool = False
    resume_round: int | None = None
    sim_seconds: float = 0.0
    round_traces: int = 0
    yhat_chains: np.ndarray = None

    def alive_mask(self) -> jnp.ndarray:
        return jnp.asarray(self.alive, jnp.float32)

    def quarantined(self) -> list:
        return [int(c) for c in np.nonzero(~self.alive)[0]]

    def laggards(self) -> list:
        return [int(c) for c in
                np.nonzero(self.alive & (self.progress
                                         < self.logical_rounds))[0]]


# ----------------------------------------------------------------- runner


class ElasticRunner:
    """Drives `ChainSupervisor.run_round` under a dynamic device pool.

    One process simulates the pool (this repo's single-host idiom —
    `launch/slda_parallel.py` holds the real multi-device shard_map):
    every wall round executes the full [M]-wide compiled round once and
    a selective merge keeps only the ACTIVE chains' new state, so chains
    at different logical rounds, on any placement, share one jit cache
    entry.  All elasticity — membership, placement, deadlines,
    restore — is host metadata between compiled calls.
    """

    def __init__(self, shards, cfg: SLDAConfig, *, devices=2,
                 elastic: ElasticConfig | None = None, health=None,
                 recovery=None, ckpt_dir=None, fault_hook=None,
                 backend=None, clock: VirtualClock | None = None,
                 events=(), preemption: PreemptionSignal | None = None):
        self.elastic = elastic or ElasticConfig()
        if cfg.n_iters % self.elastic.round_iters:
            raise ValueError(
                f"round_iters={self.elastic.round_iters} must divide "
                f"cfg.n_iters={cfg.n_iters}: elastic replay needs every "
                "round to be the same compiled computation")
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self.sup = ChainSupervisor(
            shards, cfg, health=health, recovery=recovery,
            ckpt_dir=ckpt_dir, round_iters=self.elastic.round_iters,
            fault_hook=fault_hook, backend=backend,
            keep_checkpoints=self.elastic.keep_checkpoints)
        self.pool = DevicePool(devices)
        self.clock = clock or VirtualClock()
        self.events = sorted(events, key=lambda e: e.at_round)
        self.preemption = preemption or PreemptionSignal()
        if ckpt_dir is not None:
            mgr_cls = (AsyncCheckpointManager if self.elastic.async_ckpt
                       else CheckpointManager)
            self.manager = mgr_cls(ckpt_dir,
                                   interval=self.elastic.ckpt_every,
                                   keep=self.elastic.keep_checkpoints)
        else:
            self.manager = None
        # selective merge: keep `new` only where the chain was active
        # this wall round — a frozen chain's state passes through
        # bit-identically (jnp.where copies bits, it does not recompute)
        self._merge = jax.jit(lambda new, old, act: jax.tree.map(
            lambda n, o: jnp.where(
                act.reshape((act.shape[0],) + (1,) * (n.ndim - 1)), n, o),
            new, old))

    # ---- host bookkeeping helpers -------------------------------------

    def _extra(self, bk, wall):
        return {"progress": [int(x) for x in bk["progress"]],
                "alive": [bool(x) for x in bk["alive"]],
                "epoch": [int(x) for x in bk["epoch"]],
                "restarts": [int(x) for x in bk["restarts"]],
                "wall_round": int(wall),
                "pool": [int(d) for d in self.pool.ids]}

    def _restore_victim(self, state, c, bk, events):
        """Device-loss recovery for chain `c`: restore its slice from
        the last DURABLE checkpoint and rewind its logical progress to
        the checkpoint's recorded value — NO PRNG-epoch bump, because
        the chain state was healthy (the environment failed) and exact
        replay of rounds s..R is precisely what makes the recovered
        chain bitwise-equal to one that never moved.  A torn/corrupt
        chain file falls back to fresh init WITH an epoch bump (that
        chain genuinely lost history)."""
        durable = self.manager.latest_durable()
        if durable is None:
            bk["alive"][c] = False
            bk["latched"][c] |= F_KILLED
            events.append({"chain": c, "action": "quarantine_no_checkpoint"})
            return state
        tmpl = jax.tree.map(lambda x: x[c], state)
        try:
            chain_state = restore_chain(self.ckpt_dir, durable, c, tmpl)
            extra = read_manifest(self.ckpt_dir, durable).get("extra", {})
            rewind = int(extra.get("progress", [0] * (c + 1))[c])
            events.append({"chain": c, "action":
                           f"restore_step_{durable}_progress_{rewind}"})
        except Exception as e:  # noqa: BLE001 — torn file is fault-isolated
            bk["epoch"][c] += 1
            rewind = 0
            keys = jax.vmap(
                lambda k, eo: jax.random.fold_in(k, 0x5EED + eo))(
                    self._base, jnp.asarray(bk["epoch"]))
            fresh, _ = self.sup._init(self.sup.plan, keys)
            chain_state = jax.tree.map(lambda x: x[c], fresh)
            events.append({"chain": c, "action": "restore_corrupt_fresh",
                           "error": repr(e)})
        bk["progress"][c] = rewind
        # amnesty while it replays: its MSE is legitimately behind the
        # ensemble until it catches back up
        bk["grace"][c] = int(max(bk["progress"]) - rewind) + 1
        return jax.tree.map(lambda x, xc: x.at[c].set(xc), state,
                            chain_state)

    def _repack(self, bk, placements, why):
        alive_chains = [c for c in range(len(bk["alive"]))
                        if bk["alive"][c]]
        self.placement = compute_placement(alive_chains, self.pool.ids)
        placements.append({"why": why, "pool_epoch": self.pool.epoch,
                           "placement": {str(d): list(cs) for d, cs
                                         in self.placement.items()}})

    def _apply_event(self, ev, state, bk, events, placements, straggles):
        if ev.kind == "preempt":
            self.preemption.set()
            events.append({"action": "preempt_notice"})
        elif ev.kind == "device_loss":
            if not self.pool.lose(ev.device):
                events.append({"action": "device_loss_noop",
                               "device": ev.device})
                return state
            victims = [c for c in self.placement.get(ev.device, ())
                       if bk["alive"][c]]
            events.append({"action": "device_loss", "device": ev.device,
                           "victims": victims})
            if self.manager is not None:
                # settle the in-flight async write first: the snapshot
                # for the last completed round is already taken, so the
                # wait costs nothing and every victim then restores from
                # the SAME (newest) step — deterministic recovery that
                # loses zero completed rounds
                self.manager.flush()
            for c in victims:
                if self.manager is None:
                    bk["alive"][c] = False
                    bk["latched"][c] |= F_KILLED
                    events.append({"chain": c,
                                   "action": "quarantine_no_checkpoint"})
                else:
                    state = self._restore_victim(state, c, bk, events)
            self._repack(bk, placements, f"device_loss:{ev.device}")
        elif ev.kind == "device_join":
            if self.pool.join(ev.device):
                events.append({"action": "device_join",
                               "device": ev.device})
                self._repack(bk, placements, f"device_join:{ev.device}")
        elif ev.kind == "straggle":
            straggles.append([ev.device, float(ev.delay_s),
                              int(ev.rounds)])
            events.append({"action": "straggle_start",
                           "device": ev.device, "delay_s": ev.delay_s,
                           "rounds": ev.rounds})
        else:
            raise ValueError(f"unknown elastic event kind {ev.kind!r}")
        return state

    def _round_clock(self, bk, events, placements, straggles, late):
        """Advance the virtual clock by this wall round's slowest device
        and apply the straggler policy (flag → escalate → optionally
        re-place).  Returns the per-device finish times."""
        el = self.elastic
        finish = {}
        for dev in self.pool.ids:
            delay = sum(s[1] for s in straggles
                        if s[0] == dev and s[2] > 0)
            finish[dev] = el.device_round_s + delay
        for s in straggles:
            if s[2] > 0:
                s[2] -= 1
        self.clock.advance(max(finish.values()) if finish else 0.0)
        if el.deadline_s is None:
            return finish
        on_time = [d for d in self.pool.ids
                   if finish[d] <= el.deadline_s]
        for dev in list(self.pool.ids):
            if finish[dev] <= el.deadline_s:
                late[dev] = 0
                continue
            late[dev] = late.get(dev, 0) + 1
            for c in self.placement.get(dev, ()):
                bk["latched"][c] |= F_STRAGGLER
            events.append({"action": "deadline_miss", "device": dev,
                           "finish_s": finish[dev],
                           "consecutive": late[dev]})
            if late[dev] >= el.straggle_rounds and len(self.pool) > 1:
                # slow is not dead: evict the DEVICE, keep the chains —
                # their state is correct and placement-invariant
                self.pool.lose(dev)
                events.append({"action": "straggler_evicted",
                               "device": dev})
                self._repack(bk, placements, f"straggler:{dev}")
            elif el.speculative_replace and on_time:
                target = min(on_time,
                             key=lambda d: len(self.placement.get(d, ())))
                moved = self.placement.get(dev, ())
                if moved and target != dev:
                    self.placement[target] = tuple(
                        sorted(self.placement.get(target, ()) + moved))
                    self.placement[dev] = ()
                    events.append({"action": "speculative_replace",
                                   "device": dev, "target": target,
                                   "chains": list(moved)})
                    placements.append(
                        {"why": f"speculative:{dev}->{target}",
                         "pool_epoch": self.pool.epoch,
                         "placement": {str(d): list(cs) for d, cs
                                       in self.placement.items()}})
        return finish

    def _drain(self, state, bk, wall, events):
        """Graceful preemption drain: flush the in-flight async write,
        publish a final synchronous checkpoint carrying the complete
        host bookkeeping, and leave the run resumable.  Total loss on
        resume: the (at most one) round that was in flight when the
        notice arrived."""
        if self.manager is not None:
            # the drain save is unconditional (ignores ckpt_every) and
            # synchronous: the process is about to die and this state is
            # the cheapest round to not lose
            self.manager.flush()
            save_checkpoint(self.ckpt_dir, wall,
                            jax.tree.map(lambda x: np.array(
                                jax.device_get(x)), state),
                            extra=self._extra(bk, wall))
            self.manager._gc()
        events.append({"action": "preempt_drain", "wall_round": wall,
                       "durable": (self.manager.latest_durable()
                                   if self.manager else None)})

    # ---- the wall-round loop ------------------------------------------

    def train(self, root_key, *, resume: bool = False):
        """Train M chains elastically from a single root key (per-chain
        lanes are `fold_in(root, chain_id)` — stable under any pool
        size, which is what makes placement bitwise-irrelevant).
        Returns (GibbsState, SLDAModel, ElasticReport).  With
        `resume=True`, continues from the latest durable checkpoint in
        `ckpt_dir` (fresh start if there is none)."""
        sup, el = self.sup, self.elastic
        plan = sup.plan
        m = plan.n_chains
        R = self.cfg.n_iters // el.round_iters
        round_plan = sup.make_round_plan(el.round_iters)
        bpr = round_plan.n_boundaries()

        chain_keys = jax.vmap(
            lambda c: jax.random.fold_in(root_key, c))(jnp.arange(m))
        ks = jax.vmap(jax.random.split)(chain_keys)
        state, z_fill = sup._init(plan, ks[:, 0])
        self._base = base = ks[:, 1]

        bk = {"alive": np.ones(m, bool), "epoch": np.zeros(m, np.int32),
              "restarts": np.zeros(m, np.int32),
              "grace": np.zeros(m, np.int32),
              "latched": np.zeros(m, np.uint32),
              "progress": np.zeros(m, np.int32)}
        wall = 0
        resumed_from = None
        if resume:
            if self.manager is None:
                raise ValueError("resume=True needs a ckpt_dir")
            durable = self.manager.latest_durable()
            if durable is not None:
                extra = read_manifest(self.ckpt_dir,
                                      durable).get("extra", {})
                fresh = state
                state, _info = restore_elastic(
                    self.ckpt_dir, durable, state,
                    lambda i: jax.tree.map(lambda x: x[i], fresh))
                for name in ("progress", "alive", "epoch", "restarts"):
                    if name in extra:
                        bk[name][:] = np.asarray(extra[name])
                wall = int(extra.get("wall_round", durable))
                resumed_from = durable
        history, placements = [], []
        straggles, late = [], {}
        self._repack(bk, placements, "resume" if resumed_from is not None
                     else "init")
        pending = list(self.events)
        max_wall = R * (2 + m * max(1, sup.recovery.max_restarts))

        while True:
            active = bk["alive"] & (bk["progress"] < R)
            if not active.any():
                break
            if not el.catch_up and wall >= R:
                break
            if wall >= max_wall:
                raise RuntimeError(
                    f"elastic loop exceeded {max_wall} wall rounds — "
                    "restart thrash; see the event history")
            events = []
            for ev in [e for e in pending if e.at_round <= wall]:
                pending.remove(ev)
                state = self._apply_event(ev, state, bk, events,
                                          placements, straggles)
            if self.preemption.triggered:
                self._drain(state, bk, wall, events)
                history.append({"wall_round": wall, "events": events})
                break
            active = bk["alive"] & (bk["progress"] < R)
            if not active.any():
                history.append({"wall_round": wall, "events": events})
                break

            keys = sup._fold_keys(base, bk["epoch"], bk["progress"])
            it0 = int(bk["progress"].min()) * bpr
            new_state, status_np = sup.run_round(
                round_plan, keys, state, bk["alive"], it0)
            state = self._merge(new_state, state,
                                jnp.asarray(active, bool))
            status_np = np.where(active, status_np, 0).astype(np.uint32)
            state = sup._apply_recovery(
                state, status_np, alive=bk["alive"], epoch=bk["epoch"],
                restarts=bk["restarts"], grace=bk["grace"], base=base,
                events=events)
            reset = set()
            for e in events:
                # a health-probe restart resets that chain's logical
                # clock: a restore replays from the checkpoint's round,
                # a fresh init starts over (its stream is new anyway)
                if e.get("action", "").startswith("restart_from_step_"):
                    step = int(e["action"].rsplit("_", 1)[1])
                    xtra = read_manifest(self.ckpt_dir,
                                         step).get("extra", {})
                    prog = xtra.get("progress")
                    bk["progress"][e["chain"]] = (
                        int(prog[e["chain"]]) if prog is not None else 0)
                    reset.add(e["chain"])
                elif e.get("action") == "restart_fresh_init":
                    bk["progress"][e["chain"]] = 0
                    reset.add(e["chain"])
            bk["grace"] = np.maximum(bk["grace"] - 1, 0)
            bk["latched"] |= status_np
            sup._check_min_alive(bk["alive"], bk["latched"])
            # restarted chains rewound their clock this round — the work
            # they did is gone, so they take no progress credit
            advance = active & bk["alive"]
            for c in reset:
                advance[c] = False
            bk["progress"] = bk["progress"] + advance.astype(np.int32)
            finish = self._round_clock(bk, events, placements, straggles,
                                       late)
            wall += 1
            if self.manager is not None:
                self.manager.maybe_save(wall, state,
                                        extra=self._extra(bk, wall))
            history.append({"wall_round": wall,
                            "progress": [int(x) for x in bk["progress"]],
                            "status": [int(s) for s in status_np],
                            "finish_s": {str(d): t
                                         for d, t in finish.items()},
                            "events": events})

        if self.manager is not None and not self.preemption.triggered:
            self.manager.flush()
        models = plan._export(state)
        state = GibbsState(z=plan.corpus.merge_padded(state.z, z_fill),
                           ndt=state.ndt, ntw=state.ntw, nt=state.nt,
                           eta=state.eta)
        report = ElasticReport(
            alive=bk["alive"], status=bk["latched"],
            restarts=bk["restarts"], progress=bk["progress"],
            wall_rounds=wall, logical_rounds=R, history=history,
            pool_history=list(self.pool.history), placements=placements,
            preempted=self.preemption.triggered,
            resume_round=resumed_from, sim_seconds=self.clock.now(),
            round_traces=sup.round_traces)
        return state, models, report


# --------------------------------------------------- end-to-end entry point


def elastic_run_average(key, train, test, cfg: SLDAConfig, m: int, *,
                        devices=2, rule: str = "weighted",
                        elastic: ElasticConfig | None = None, health=None,
                        recovery=None, ckpt_dir=None, events=(),
                        clock=None, preemption=None, resume: bool = False):
    """The elastic form of `supervised_run_average`: train M chains
    under the elastic runtime, predict with every chain, combine with
    the final alive mask.  Returns (ŷ [D_test], ElasticReport)."""
    from repro.core import combine
    from repro.core.parallel import _combine_weighted, _predict_chains_jit
    from repro.core.types import _concat_corpora
    k1, k2 = jax.random.split(key)
    shards = build_schedule(partition(train, m), cfg)
    runner = ElasticRunner(shards, cfg, devices=devices, elastic=elastic,
                           health=health, recovery=recovery,
                           ckpt_dir=ckpt_dir, events=events, clock=clock,
                           preemption=preemption)
    _, models, report = runner.train(k1, resume=resume)
    alive = report.alive_mask()
    if rule == "weighted" and cfg.fuse_weighted_predict:
        both = _concat_corpora(test, train)
        yhat = _predict_chains_jit(k2, models, build_schedule(both, cfg),
                                   cfg)
        yhat_te, yhat_tr = yhat[:, :test.n_docs], yhat[:, test.n_docs:]
    else:
        yhat_te = _predict_chains_jit(k2, models,
                                      build_schedule(test, cfg), cfg)
        yhat_tr = None
    report.yhat_chains = np.asarray(jax.device_get(yhat_te))
    if rule == "simple":
        return combine.simple_average(yhat_te, alive=alive), report
    if rule == "median":
        return combine.median(yhat_te, alive=alive), report
    if rule == "weighted":
        if yhat_tr is None:
            k3 = jax.random.fold_in(k2, 1)
            yhat_tr = _predict_chains_jit(k3, models,
                                          build_schedule(train, cfg), cfg)
        return _combine_weighted(yhat_te, yhat_tr, train.y, cfg,
                                 alive), report
    raise ValueError(rule)
