"""End-to-end LM trainer with communication-free chain parallelism,
checkpoint/restart and per-chain metrics.

CPU-runnable (smoke configs):
  python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq 64 --chains 2 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_elastic
from repro.configs import get_arch
from repro.data import synthetic_lm_batch
from repro.metrics import MetricLogger, ensemble_health
from repro.models import init_params
from repro.optim import OptConfig, init_opt_state
from .sharding import DistConfig
from .steps import make_train_step


def make_lm_batch(seed, step, cfg, n_chains, batch, seq):
    """Per-chain disjoint data shards (the paper's partition step): chain i
    draws from stream offset i — no two chains ever see the same batch."""
    out = {"tokens": [], "targets": []}
    for c in range(n_chains):
        b = synthetic_lm_batch(seed + 7919 * c, step, batch, seq,
                               cfg.vocab_size)
        out["tokens"].append(b["tokens"])
        out["targets"].append(b["targets"])
    batch_tree = {k: jnp.stack(v) for k, v in out.items()}
    if cfg.frontend == "vision":
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        batch_tree["embeds"] = jax.random.normal(
            key, (n_chains, batch, cfg.n_patches, cfg.d_model))
    elif cfg.frontend == "audio":
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        batch_tree["embeds"] = jax.random.normal(
            key, (n_chains, batch, seq, cfg.d_model))
    return batch_tree


def train(arch: str, *, smoke=True, steps=50, batch=8, seq=64, chains=2,
          lr=3e-4, seed=0, ckpt_dir=None, save_interval=20, resume=False,
          accum=1, compute_dtype="float32", log_every=10,
          schedule_steps=None, metrics_path=None):
    cfg = get_arch(arch, smoke=smoke)
    dist = DistConfig(n_chains=chains, accum_steps=accum,
                      compute_dtype=compute_dtype, use_pallas=False,
                      remat=False)
    sched = schedule_steps or steps   # keep fixed across restarts
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, sched // 10),
                        total_steps=sched)

    key = jax.random.PRNGKey(seed)
    init_chain = lambda i: init_params(jax.random.fold_in(key, i), cfg, 1)
    params = init_params(key, cfg, chains)
    opt_state = init_opt_state(params, opt_cfg)
    start = 0

    manager = CheckpointManager(ckpt_dir, save_interval) if ckpt_dir else None
    if resume and ckpt_dir and latest_step(ckpt_dir) is not None:
        step0 = latest_step(ckpt_dir)
        state = {"params": params, "opt": opt_state}
        state, info = restore_elastic(
            ckpt_dir, step0, state,
            lambda i: {"params": jax.tree.map(lambda x: x[0],
                                              init_chain(i)),
                       "opt": jax.tree.map(
                           lambda x: x[0],
                           init_opt_state(init_chain(i), opt_cfg))})
        params, opt_state = state["params"], state["opt"]
        # opt step counter must be a scalar again after chain stacking
        opt_state["step"] = jnp.max(opt_state["step"])
        start = step0
        print(f"resumed at step {step0}, chains restored: "
              f"{info['restored_chains']}")

    step_fn = jax.jit(make_train_step(cfg, dist, opt_cfg), donate_argnums=(0, 1))
    logger = MetricLogger(metrics_path)
    history = []
    for step in range(start, steps):
        batch_tree = make_lm_batch(seed, step, cfg, chains, batch, seq)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_tree)
        loss = np.asarray(metrics["loss"])
        history.append(loss)
        alive, health = ensemble_health(loss)
        logger.log(step, loss=loss, grad_norm=np.asarray(
            metrics["grad_norm"]), alive=np.asarray(alive),
            step_s=time.time() - t0)
        if step % log_every == 0 or step == steps - 1:
            note = "" if float(alive.sum()) == chains else \
                f"  [!] dead chains: {np.where(np.asarray(alive) == 0)[0]}"
            print(f"step {step:5d}  loss/chain "
                  f"{np.array2string(loss, precision=3)}  "
                  f"({time.time() - t0:.2f}s){note}")
        if manager:
            manager.maybe_save(step + 1,
                               {"params": params, "opt": opt_state})
    return params, opt_state, np.stack(history)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--chains", type=int, default=2)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-interval", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, chains=args.chains, lr=args.lr, seed=args.seed,
          ckpt_dir=args.ckpt_dir, save_interval=args.save_interval,
          resume=args.resume, accum=args.accum)


if __name__ == "__main__":
    main()
