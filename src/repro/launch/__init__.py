"""Distribution + launch layer: production meshes, sharding rules,
train/serve step builders, the multi-pod dry-run, and the sLDA chain
runner."""
