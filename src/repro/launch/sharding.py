"""Sharding rules: how the chain / data / model axes map onto every tensor.

Chain placement (DESIGN.md §4): the chain axis is the paper's
communication-free boundary.  Valid chain counts are constrained by the
mesh — a chain count must exactly cover whole mesh axes:

  single-pod (data=16, model=16):   1 | 16
  multi-pod (pod=2, data=16, model=16):   1 | 2 | 32

`n_chains=1` on the multi-pod mesh is the *standard data-parallel
baseline* (gradient all-reduce crosses the pod boundary) — it exists so
the dry-run can quantify exactly how many inter-pod collective bytes the
paper's technique removes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DistConfig:
    n_chains: int = 1
    fsdp: bool = False
    accum_steps: int = 1
    param_dtype: str = "float32"
    opt_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    use_pallas: bool = False       # False → partitionable jnp twins (dry-run)
    remat: bool = True
    # --- §Perf switches (all False/off in the paper-faithful baseline) ---
    opt_causal_attention: bool = False   # triangular-scan causal skip
    opt_replicate_embed: bool = False    # replicate untied embed table over
                                         # 'model' (kills the gather reshard)
    opt_prefill_last_only: bool = False  # prefill emits last-token logits
    opt_attn_block_q: int = 0            # 0 = default; S = scan-free attn
    opt_head_shard: bool = False         # head-aligned q/k/v constraints
    opt_probs_bf16: bool = False         # bf16 attention probabilities
    opt_moe_ep: bool = False             # explicit EP constraint on MoE
    remat_policy: str = "full"           # "full" | "dots" (save matmul outs)


def axis_sizes(mesh) -> dict:
    # works for both Mesh and AbstractMesh
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def chain_axes(mesh: Mesh, n_chains: int) -> tuple:
    sizes = axis_sizes(mesh)
    multi = "pod" in sizes
    if n_chains == 1:
        return ()
    if multi and n_chains == sizes["pod"]:
        return ("pod",)
    if multi and n_chains == sizes["pod"] * sizes["data"]:
        return ("pod", "data")
    if not multi and n_chains == sizes["data"]:
        return ("data",)
    raise ValueError(
        f"n_chains={n_chains} must cover whole mesh axes of {sizes}")


def dp_axes(mesh: Mesh, n_chains: int) -> tuple:
    used = set(chain_axes(mesh, n_chains))
    return tuple(a for a in mesh.axis_names if a != "model" and a not in used)


def _maybe(axes):
    """() → None, ('data',) → 'data', tuple stays tuple."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _fits(shape, spec, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    sizes = axis_sizes(mesh)
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def param_specs(params, cfg_mesh: Mesh, dist: DistConfig):
    """PartitionSpec tree matching the param tree (rules in DESIGN.md §6)."""
    c = _maybe(chain_axes(cfg_mesh, dist.n_chains))
    f = "data" if (dist.fsdp and "data" in dp_axes(cfg_mesh, dist.n_chains)) \
        else None
    m = "model"

    def rule(path, leaf):
        name = None
        stacked = False
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                if p.key == "layers_stacked":
                    stacked = True     # leaves carry a leading layer dim
                name = p.key
        nd = leaf.ndim - (1 if stacked else 0)
        if name == "table":
            # §Perf: replicating the (untied) embed table over 'model'
            # turns the token gather into a local lookup (no reshard)
            spec = (c, None, None) if dist.opt_replicate_embed else (c, m, f)
        elif name in ("lm_head", "frontend_proj"):
            spec = (c, f, m)
        elif name in ("wq", "wk", "wv", "wz", "wx", "wbc", "wdt"):
            spec = (c, f, m)
        elif name in ("w_gate", "w_up"):
            spec = (c, m, f, None) if nd == 4 else (c, f, m)   # moe | mlp
        elif name == "w_down":
            spec = (c, m, None, f) if nd == 4 else (c, m, f)
        elif name in ("wo", "out_proj"):
            spec = (c, m, f)
        elif name in ("bq", "bk", "bv", "conv_b_x", "conv_b_bc", "out_norm",
                      "A_log", "dt_bias"):
            spec = (c, m)
        elif name in ("conv_x", "conv_bc"):
            spec = (c, None, m)
        elif name == "router":
            spec = (c, None, None)
        else:                       # norms, q_norm/k_norm, small leaves
            spec = (c,) + (None,) * (nd - 1)
        spec = spec[:nd]
        if stacked:
            spec = (None,) + spec   # layer dim of scanned stacks: unsharded
        return _fits(leaf.shape, spec, cfg_mesh)

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_specs(batch, mesh: Mesh, dist: DistConfig, *, replicated_serve=False):
    """Batch sharding: train batches split over chains×dp; serve batches
    (replicated_serve) shard over dp only and replicate across chains."""
    c = _maybe(chain_axes(mesh, dist.n_chains))
    d = _maybe(dp_axes(mesh, dist.n_chains))
    b_axis = None if replicated_serve and c is not None else d

    def rule(_, leaf):
        spec = (c, b_axis) + (None,) * (leaf.ndim - 2)
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_specs(cache, mesh: Mesh, dist: DistConfig):
    """KV/SSM cache sharding: batch over dp; kv-heads over model when
    divisible, else the cache SEQ dim over model (context sharding), else
    replicated.  SSM states shard heads over model."""
    c = _maybe(chain_axes(mesh, dist.n_chains))
    d = _maybe(dp_axes(mesh, dist.n_chains))
    msize = axis_sizes(mesh)["model"]

    def rule(path, leaf):
        name = None
        stacked = False
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                if p.key == "layers_stacked":
                    stacked = True
                name = p.key
        shape = leaf.shape[1:] if stacked and name != "pos" else leaf.shape
        if name in ("len", "pos"):
            spec = (c, d)
        elif name in ("k", "v"):                 # [C, b, Hkv, S, hd]
            if shape[2] % msize == 0:
                spec = (c, d, "model", None, None)
            elif shape[3] % msize == 0:
                spec = (c, d, None, "model", None)
            else:
                spec = (c, d, None, None, None)
        elif name == "ssm":                      # [C, b, H, P, N]
            spec = (c, d, "model", None, None)
        elif name in ("conv_x", "conv_bc"):      # [C, b, K-1, ch]
            spec = (c, d, None, "model")
        else:
            spec = (c, d) + (None,) * (leaf.ndim - 2)
        nd = leaf.ndim - (1 if stacked and name != "pos" else 0)
        spec = spec[:nd]
        if stacked and name != "pos":
            spec = (None,) + spec
        return _fits(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(pspecs, mesh: Mesh):
    """Optimizer state mirrors param sharding; the step counter replicates."""
    return {"m": pspecs, "v": pspecs, "step": P()}
