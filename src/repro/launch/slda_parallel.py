"""Multi-device sLDA chain runner: the paper's algorithm under shard_map.

Each mesh slice owns `chains_per_device` chains and their training
shards, so the paper's M is decoupled from the device count:
M = mesh.shape[axis] × chains_per_device.  The local chain batch runs
through the CHAIN-BATCHED core entry points
(`core.parallel.train_chains_keyed` / `predict_chains_keyed`), which on
TPU lower to the grid-(chains, doc_blocks) fused Pallas launches of
DESIGN.md §Chain-batched — one launch per EM boundary for all local
chains, the shared test-token tiles read once per doc block rather than
once per chain.

The training phase contains ZERO collectives — `shard_map` makes that
structural, not accidental: the per-slice function has no `psum`/`all_*`
in it (the chain batch is slice-local), so the lowered HLO cannot
contain a collective.  The only communication in the whole algorithm is
the final `all_gather` of the per-chain test predictions (a [D_test]
float vector each — KBs), which implements the paper's combination
stage (Eq. 6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (Corpus, SLDAConfig, build_schedule, combine,
                        devices_support_pallas, partition)
from repro.core.parallel import predict_chains_keyed, train_chains_keyed


def mesh_supports_pallas(mesh: Mesh) -> bool:
    """True when every device in the mesh compiles the sLDA Pallas kernels
    natively (TPU).  On CPU/GPU meshes the kernels would run in interpret
    mode — correct but slower than the batched-jnp twins, so the runner
    keeps use_pallas off there.  (Thin alias of the shared
    `core.devices_support_pallas` predicate — the one platform check,
    also behind `SLDAConfig.resolve_backend`.)"""
    return devices_support_pallas(mesh.devices.flat)


def parallel_slda_shard_map(key, train: Corpus, test: Corpus,
                            cfg: SLDAConfig, mesh: Mesh,
                            axis: str = "data", rule: str = "simple",
                            auto_pallas: bool = True,
                            chains_per_device: int | None = None,
                            alive=None, auto_quarantine: bool = True,
                            return_report: bool = False):
    """Run M = mesh.shape[axis] × chains_per_device chains, a chain batch
    per mesh slice, then combine predictions.  Returns ŷ [D_test].

    chains_per_device=None reads `cfg.chains_per_device` (default 1 —
    the one-chain-per-device special case).  auto_pallas=True flips
    `cfg.use_pallas` on when the mesh backend compiles the kernels
    natively (TPU), so chains take the fused chain-batched kernel paths
    without the caller having to re-tune the config per backend; an
    explicit `use_pallas=True` in cfg is always honored (including
    interpret mode on CPU meshes, which the communication-freedom test
    exercises).

    cfg.length_buckets > 0 routes the chain phases through the ragged
    execution layer (DESIGN.md §Ragged-execution): shards and test are
    length-bucketed HERE — outside shard_map, where lengths are concrete
    — and the bucketed pytrees flow through the same per-slice chain
    functions (every bucket's arrays carry the chain dim, so the specs
    below still shard only that axis; zero collectives is untouched).

    Fault tolerance (DESIGN.md §Fault-model): `alive` [M] masks chains
    out of the combine — communication-freedom makes the drop EXACT.
    With `alive=None` and `auto_quarantine=True` (default), any chain
    whose gathered predictions or train stats came back non-finite is
    quarantined automatically — a NaN-poisoned replica cannot
    contaminate ŷ.  When every chain is healthy the mask is all-ones,
    which evaluates to the identical combine expressions, so healthy
    runs are unchanged.  `return_report=True` additionally returns
    {"alive": ..., "n_quarantined": ...}."""
    if auto_pallas and not cfg.use_pallas and mesh_supports_pallas(mesh):
        cfg = dataclasses.replace(cfg, use_pallas=True)
    cpd = cfg.chains_per_device if chains_per_device is None \
        else chains_per_device
    mesh_m = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    m = mesh_m * cpd
    shards = partition(train, m)                      # [M, D/M, ...]
    shard_spec, test_spec = P(axis), P()
    if cfg.length_buckets > 0:
        # schedules are built HERE — outside shard_map, where lengths
        # are concrete; inside each slice `train_chains_keyed` builds
        # its plan from the sharded schedule (plan per shard)
        shards = build_schedule(shards, cfg)
        test = build_schedule(test, cfg)
        shard_spec = jax.tree.map(lambda _: P(axis), shards)
        test_spec = jax.tree.map(lambda _: P(), test)

    def chain_fn(key_rep, shard_blk, test_blk):
        # cpd chains per mesh slice: the in_spec hands this slice cpd
        # consecutive shards.  Chain keys are folded from the replicated
        # base key INSIDE the shard, one per GLOBAL chain id — a
        # pre-split [M, 2] keys array sharded over `axis` makes GSPMD
        # lower the threefry split as a cross-device combine (an
        # all-reduce), which would break the zero-collective guarantee.
        base = jax.lax.axis_index(axis) * cpd
        keys = jax.vmap(lambda i: jax.random.fold_in(key_rep, base + i))(
            jnp.arange(cpd))
        ks = jax.vmap(jax.random.split)(keys)         # [cpd, 2, key]
        _, models = train_chains_keyed(ks[:, 0], shard_blk, cfg)  # NO collectives
        yhat = predict_chains_keyed(ks[:, 1], models, test_blk, cfg)
        stats = jnp.stack([models.train_mse, models.train_acc], axis=-1)
        # the ONLY communication in the algorithm:
        yhat_all = jax.lax.all_gather(yhat, axis)     # [mesh_m, cpd, D_test]
        stats_all = jax.lax.all_gather(stats, axis)   # [mesh_m, cpd, 2]
        return (yhat_all.reshape(m, yhat.shape[-1]),
                stats_all.reshape(m, 2))

    fn = shard_map(
        chain_fn, mesh=mesh,
        in_specs=(P(), shard_spec, test_spec),
        out_specs=(P(), P()),
        check_rep=False,   # chain-local scans carry unvarying state
    )
    yhat_all, stats_all = fn(key, shards, test)
    if alive is None and auto_quarantine:
        # the gathered per-chain vectors are the chain's only output —
        # a non-finite row means the chain is unusable, full stop
        alive = (jnp.isfinite(yhat_all).all(axis=-1)
                 & jnp.isfinite(stats_all).all(axis=-1)).astype(jnp.float32)
    if rule == "simple":
        yhat = combine.simple_average(yhat_all, alive=alive)
    elif rule == "weighted":
        if cfg.label_type == "binary":
            yhat = combine.weighted_average(yhat_all,
                                            train_acc=stats_all[:, 1],
                                            alive=alive)
        else:
            yhat = combine.weighted_average(yhat_all,
                                            train_mse=stats_all[:, 0],
                                            alive=alive)
    elif rule == "median":
        yhat = combine.median(yhat_all, alive=alive)
    else:
        raise ValueError(rule)
    if return_report:
        a = None if alive is None else jnp.asarray(alive)
        report = {"alive": a,
                  "n_quarantined": (0 if a is None
                                    else int(m - float(a.sum())))}
        return yhat, report
    return yhat
