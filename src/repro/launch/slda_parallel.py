"""Multi-device sLDA chain runner: the paper's algorithm under shard_map.

Each device (or device group) owns one chain and its training shard.  The
training phase contains ZERO collectives — `shard_map` makes that
structural, not accidental: the per-chain function has no `psum`/`all_*`
in it, so the lowered HLO cannot contain a collective.  The only
communication in the whole algorithm is the final `all_gather` of the
per-chain test predictions (a [D_test] float vector each — KBs), which
implements the paper's combination stage (Eq. 6).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import (Corpus, SLDAConfig, combine, partition,
                        predict, train_chain)


def mesh_supports_pallas(mesh: Mesh) -> bool:
    """True when every device in the mesh compiles the sLDA Pallas kernels
    natively (TPU).  On CPU/GPU meshes the kernels would run in interpret
    mode — correct but slower than the batched-jnp twins, so the runner
    keeps use_pallas off there."""
    return all(d.platform == "tpu" for d in mesh.devices.flat)


def parallel_slda_shard_map(key, train: Corpus, test: Corpus,
                            cfg: SLDAConfig, mesh: Mesh,
                            axis: str = "data", rule: str = "simple",
                            auto_pallas: bool = True):
    """Run M = mesh.shape[axis] chains, one per mesh slice, then combine
    predictions.  Returns ŷ [D_test].

    auto_pallas=True flips `cfg.use_pallas` on when the mesh backend
    compiles the kernels natively (TPU), so chains take the fused
    train/predict kernel paths without the caller having to re-tune the
    config per backend; an explicit `use_pallas=True` in cfg is always
    honored (including interpret mode on CPU meshes, which the
    communication-freedom test exercises)."""
    if auto_pallas and not cfg.use_pallas and mesh_supports_pallas(mesh):
        cfg = dataclasses.replace(cfg, use_pallas=True)
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    shards = partition(train, m)                      # [M, D/M, ...]

    def chain_fn(key_rep, shard_blk, test_blk):
        # one chain per mesh slice: leading dim 1 inside the block.  The
        # chain key is folded from the replicated base key INSIDE the shard
        # — a pre-split [M, 2] keys array sharded over `axis` makes GSPMD
        # lower the threefry split as a cross-device combine (an
        # all-reduce), which would break the zero-collective guarantee.
        k = jax.random.fold_in(key_rep, jax.lax.axis_index(axis))
        shard = jax.tree.map(lambda x: x[0], shard_blk)
        k1, k2 = jax.random.split(k)
        _, model = train_chain(k1, shard, cfg)        # NO collectives
        yhat = predict(k2, model, test_blk, cfg)      # local prediction
        stats = jnp.stack([model.train_mse, model.train_acc])
        # the ONLY communication in the algorithm:
        yhat_all = jax.lax.all_gather(yhat, axis)     # [M, D_test]
        stats_all = jax.lax.all_gather(stats, axis)   # [M, 2]
        return yhat_all, stats_all

    fn = shard_map(
        chain_fn, mesh=mesh,
        in_specs=(P(), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False,   # chain-local scans carry unvarying state
    )
    yhat_all, stats_all = fn(key, shards, test)
    if rule == "simple":
        return combine.simple_average(yhat_all)
    if rule == "weighted":
        if cfg.label_type == "binary":
            return combine.weighted_average(yhat_all,
                                            train_acc=stats_all[:, 1])
        return combine.weighted_average(yhat_all, train_mse=stats_all[:, 0])
    if rule == "median":
        return combine.median(yhat_all)
    raise ValueError(rule)
