"""Assigned input-shape sets and ShapeDtypeStruct factories for the dry-run.

Every LM arch carries the same 4 shapes (assignment):
  train_4k     seq 4096  × global_batch 256   → lowers train_step
  prefill_32k  seq 32768 × global_batch 32    → lowers prefill (forward)
  decode_32k   cache 32768 × global_batch 128 → lowers serve_step (1 token)
  long_500k    cache 524288 × global_batch 1  → serve_step; sub-quadratic
                                                archs only (DESIGN.md §5)

`input_specs` returns weak-type-correct jax.ShapeDtypeStruct stand-ins — no
device allocation, the same pattern the dry-run compiles against.

Chain semantics (paper, DESIGN.md §4): training batches are SPLIT across
chains; serving batches are REPLICATED per chain (every chain predicts all
requests; predictions are then combined — Eq. 6 of the paper).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ModelConfig) -> list[str]:
    """The shape cells this arch runs (long_500k only if sub-quadratic)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec, n_chains: int,
                compute_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell."""
    i32 = jnp.int32
    C = n_chains
    if shape.kind == "train":
        assert shape.global_batch % C == 0, (shape.name, C)
        b = shape.global_batch // C
        spec = {"tokens": jax.ShapeDtypeStruct((C, b, shape.seq_len), i32),
                "targets": jax.ShapeDtypeStruct((C, b, shape.seq_len), i32)}
    elif shape.kind == "prefill":
        b = shape.global_batch          # replicated across chains (serving)
        spec = {"tokens": jax.ShapeDtypeStruct((C, b, shape.seq_len), i32)}
    else:                               # decode: 1 token vs a full cache
        b = shape.global_batch
        spec = {"tokens": jax.ShapeDtypeStruct((C, b, 1), i32)}
    if cfg.frontend == "vision" and shape.kind != "decode":
        # patch embeddings enter at prefill/train; decode reuses the cache
        spec["embeds"] = jax.ShapeDtypeStruct(
            (C, spec["tokens"].shape[1], cfg.n_patches, cfg.d_model),
            compute_dtype)
    elif cfg.frontend == "audio":
        t = spec["tokens"].shape
        spec["embeds"] = jax.ShapeDtypeStruct(
            (C, t[1], t[2], cfg.d_model), compute_dtype)
    return spec
