"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB per the assignment (precomputed frame embeddings).
[arXiv:2306.05284; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", n_layers=48, d_model=1536, n_heads=24,
    n_kv_heads=24, d_ff=6144, vocab_size=2048, rope_theta=1e4,
    frontend="audio",
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=1,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="musicgen-medium-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=128)
