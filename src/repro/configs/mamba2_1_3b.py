"""mamba2-1.3b [ssm] — attention-free SSD stack.  [arXiv:2405.21060]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280, layer_pattern="M" * 48, ssm_state=128,
    ssm_head_dim=64, tie_embeddings=True,
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=1,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-1.3b-smoke", n_layers=2, d_model=128, n_heads=1,
    n_kv_heads=1, vocab_size=512, layer_pattern="M" * 2, ssm_state=16,
    ssm_head_dim=32)
