"""qwen2.5-32b [dense] — GQA kv=8, QKV bias.  [hf:Qwen/Qwen2.5-32B; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064, head_dim=128, qkv_bias=True,
    rope_theta=1e6, scan_layers=True,   # 64 deep: scan keeps compile O(1)
)

# memory plan: too large for per-device replicas → 1 chain per pod,
# FSDP over the data axis, bf16 optimizer state (DESIGN.md §6)
RUN = dict(chains_single=1, chains_multi=2, fsdp=True, accum_steps=16,
           param_dtype="float32", opt_dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-32b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32)
