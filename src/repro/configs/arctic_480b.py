"""arctic-480b [moe] — 128 experts top-2 PLUS a dense residual MLP per
layer (Snowflake Arctic's dense-MoE hybrid).  [hf:Snowflake/snowflake-arctic-base; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000, rope_theta=1e6,
    n_experts=128, moe_top_k=2, moe_d_ff=4864, moe_dense_d_ff=4864,
)

# the heavyweight: 1 chain per pod, FSDP + expert sharding, bf16 everywhere
# (params/opt state in bf16 = 6 B/param → ~11 GB/device at 512 chips)
RUN = dict(chains_single=1, chains_multi=2, fsdp=True, accum_steps=16,
           param_dtype="bfloat16", opt_dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-480b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, n_experts=4, moe_d_ff=256,
    moe_dense_d_ff=256, capacity_factor=8.0)  # no token drops in smoke
