"""internlm2-1.8b [dense] — GQA kv=8.  [arXiv:2403.17297; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab_size=92544, rope_theta=1e6,
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=1,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="internlm2-1.8b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512)
