"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab_size=32064, rope_theta=1e6,
    n_experts=16, moe_top_k=2, moe_d_ff=6400,
)

RUN = dict(chains_single=1, chains_multi=2, fsdp=True, accum_steps=8,
           param_dtype="float32", opt_dtype="bfloat16")

SMOKE = dataclasses.replace(
    CONFIG, name="phi3.5-moe-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, n_experts=4, moe_d_ff=256,
    capacity_factor=8.0)  # no token drops in smoke
