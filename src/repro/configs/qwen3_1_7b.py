"""qwen3-1.7b [dense] — GQA kv=8 with qk_norm.  [hf:Qwen/Qwen3-1.7B; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=1,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-1.7b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32)
