"""zamba2-2.7b [hybrid] — Mamba2 backbone + ONE parameter-shared attention
block applied every 6 layers.  [arXiv:2411.15242; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", n_layers=54, d_model=2560, n_heads=32,
    n_kv_heads=32, d_ff=10240, vocab_size=32000, rope_theta=1e4,
    layer_pattern="M" * 54, ssm_state=64, ssm_head_dim=64,
    shared_attn_every=6,
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=1,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-2.7b-smoke", n_layers=6, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=512, layer_pattern="M" * 6,
    ssm_state=16, ssm_head_dim=32, shared_attn_every=3)
