"""Architecture registry: the 10 assigned archs + the paper's own sLDA
experiment configs."""
from __future__ import annotations

from repro.core.types import SLDAConfig

from . import (arctic_480b, codeqwen1_5_7b, internlm2_1_8b, internvl2_2b,
               mamba2_1_3b, musicgen_medium, phi3_5_moe_42b, qwen2_5_32b,
               qwen3_1_7b, zamba2_2_7b)
from .shapes import SHAPES, ShapeSpec, cells_for, input_specs

_MODULES = {
    "qwen2.5-32b": qwen2_5_32b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "internlm2-1.8b": internlm2_1_8b,
    "qwen3-1.7b": qwen3_1_7b,
    "arctic-480b": arctic_480b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "zamba2-2.7b": zamba2_2_7b,
    "internvl2-2b": internvl2_2b,
    "musicgen-medium": musicgen_medium,
    "mamba2-1.3b": mamba2_1_3b,
}

ARCHS = {name: m.CONFIG for name, m in _MODULES.items()}
SMOKES = {name: m.SMOKE for name, m in _MODULES.items()}
RUNS = {name: m.RUN for name, m in _MODULES.items()}


def get_arch(name: str, smoke: bool = False):
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


# ---- the paper's own experiments (Section IV) ----
SLDA_MDNA = SLDAConfig(n_topics=32, vocab_size=4238, rho=0.5,
                       label_type="continuous", n_iters=60)
SLDA_IMDB = SLDAConfig(n_topics=32, vocab_size=8000, rho=0.25,
                       label_type="binary", n_iters=60)

__all__ = ["ARCHS", "SMOKES", "RUNS", "get_arch", "SHAPES", "ShapeSpec",
           "cells_for", "input_specs", "SLDA_MDNA", "SLDA_IMDB"]
