"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA: kv=32, QKV bias).
[hf:Qwen/CodeQwen1.5-7B; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=32, d_ff=13440, vocab_size=92416, qkv_bias=True,
    rope_theta=1e6,
)

RUN = dict(chains_single=16, chains_multi=32, fsdp=False, accum_steps=4,
           param_dtype="float32", opt_dtype="float32")

SMOKE = dataclasses.replace(
    CONFIG, name="codeqwen1.5-7b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=512)
