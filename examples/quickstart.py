"""Quickstart: train a supervised topic model and predict, the paper's way.

Runs in ~1 minute on CPU:
  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import SLDAConfig, run_simple_average, run_nonparallel
from repro.data import make_slda_corpus, train_test_split

cfg = SLDAConfig(n_topics=8, vocab_size=300, n_iters=30, rho=0.25)

key = jax.random.PRNGKey(0)
corpus, true_eta = make_slda_corpus(key, n_docs=320, vocab_size=300,
                                    n_topics=8, doc_len=60, rho=0.25)
train, test = train_test_split(corpus, 256)
var_y = float(jnp.var(test.y))

# single-machine sLDA (the paper's Non-parallel benchmark)
yhat = jax.jit(run_nonparallel, static_argnums=(3,))(
    jax.random.PRNGKey(1), train, test, cfg)
mse = float(jnp.mean((yhat - test.y) ** 2))
print(f"non-parallel  : test MSE {mse:.4f}  (R² {1 - mse / var_y:.3f})")

# the paper's communication-free parallel algorithm, M=4 chains
yhat = jax.jit(run_simple_average, static_argnums=(3, 4))(
    jax.random.PRNGKey(1), train, test, cfg, 4)
mse = float(jnp.mean((yhat - test.y) ** 2))
print(f"simple average: test MSE {mse:.4f}  (R² {1 - mse / var_y:.3f})  "
      f"— 4 chains, zero training communication")

# ragged corpora need no separate API: the SAME entry point, with
# cfg.length_buckets > 0, routes through the length-bucketed execution
# plan (call it un-jitted — schedules are built from concrete lengths;
# bit-identical predictions, compute scaling with Σ true tokens).
# `python -m repro.launch.dryrun --slda-plan` shows the chosen plan.
cfg_ragged = dataclasses.replace(cfg, length_buckets=8)
yhat = run_simple_average(jax.random.PRNGKey(1), train, test, cfg_ragged, 4)
mse = float(jnp.mean((yhat - test.y) ** 2))
print(f"simple average: test MSE {mse:.4f}  (R² {1 - mse / var_y:.3f})  "
      f"— same algorithm over the ragged execution plan")
