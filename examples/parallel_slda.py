"""The paper's full Section-IV comparison + the fault-tolerance dividend.

1. Runs all four algorithms (Non-parallel, Naive Combination, Simple
   Average, Weighted Average) on an sLDA-generated corpus and prints the
   time/accuracy comparison of Figures 6-7.
2. Demonstrates what communication-free chains buy operationally: kill a
   chain after training and the combiner simply renormalizes over the
   survivors — no retraining, no resharding.

  PYTHONPATH=src python examples/parallel_slda.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import (SLDAConfig, ALGORITHMS, combine, partition,
                        predict_chains, train_chains)
from repro.data import make_slda_corpus, train_test_split

M = 4
cfg = SLDAConfig(n_topics=8, vocab_size=300, n_iters=30, rho=0.25)

key = jax.random.PRNGKey(0)
corpus, _ = make_slda_corpus(key, n_docs=400, vocab_size=300, n_topics=8,
                             doc_len=60, rho=0.25)
train, test = train_test_split(corpus, 320)
var_y = float(jnp.var(test.y))

print("=== the paper's four algorithms (Fig. 6 layout) ===")
for name in ("nonparallel", "naive", "simple", "weighted"):
    fn = ALGORITHMS[name]
    if name == "nonparallel":
        jfn = jax.jit(fn, static_argnums=(3,))
        args = (jax.random.PRNGKey(1), train, test, cfg)
    else:
        jfn = jax.jit(fn, static_argnums=(3, 4))
        args = (jax.random.PRNGKey(1), train, test, cfg, M)
    yhat = jfn(*args)
    yhat.block_until_ready()
    t0 = time.time()
    yhat = jfn(*args).block_until_ready()
    mse = float(jnp.mean((yhat - test.y) ** 2))
    print(f"  {name:12s} wall {time.time() - t0:6.2f}s   "
          f"test MSE {mse:.4f}   R² {1 - mse / var_y:.3f}")

print("\n=== same algorithms over the ragged execution plan ===")
# A length-bucketed config routes the SAME entry points through the
# ragged execution layer (DESIGN.md §Execution-plan) — no *_bucketed
# twins; call un-jitted so schedules build from concrete lengths.
import dataclasses
from repro.core import build_plan, build_schedule
cfg_ragged = dataclasses.replace(cfg, length_buckets=6)
plan = build_plan(build_schedule(train, cfg_ragged), cfg_ragged)
d = plan.describe()
print(f"  plan: executor={d['executor']} buckets={d['bucket_widths']} "
      f"slot/real tokens {d['slot_tokens_per_sweep']}/"
      f"{d['real_tokens_per_sweep']}")
yhat = ALGORITHMS["weighted"](jax.random.PRNGKey(1), train, test,
                              cfg_ragged, M)
mse = float(jnp.mean((yhat - test.y) ** 2))
print(f"  weighted (ragged plan)   test MSE {mse:.4f}   "
      f"R² {1 - mse / var_y:.3f}")

print("\n=== fault tolerance: drop a chain, renormalize, carry on ===")
models = jax.jit(train_chains, static_argnums=(2,))(
    jax.random.PRNGKey(2), partition(train, M), cfg)
yhat_all = jax.jit(predict_chains, static_argnums=(3,))(
    jax.random.PRNGKey(3), models, test, cfg)        # [M, D_test]
for alive in (jnp.ones(M), jnp.array([1.0, 0.0, 1.0, 1.0]),
              jnp.array([1.0, 0.0, 0.0, 1.0])):
    yhat = combine.weighted_average(yhat_all, train_mse=models.train_mse,
                                    alive=alive)
    mse = float(jnp.mean((yhat - test.y) ** 2))
    print(f"  chains alive {alive.astype(int).tolist()}  "
          f"test MSE {mse:.4f}")
