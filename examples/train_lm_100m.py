"""End-to-end driver: train a ~100M-param LM with communication-free chain
parallelism, checkpoint/restart, and the paper's prediction-combination at
eval time.

Full run (a few hundred steps; ~30-60 min on this CPU):
  PYTHONPATH=src python examples/train_lm_100m.py --steps 300
Smoke run:
  PYTHONPATH=src python examples/train_lm_100m.py --steps 20 --tiny
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step
from repro.data import synthetic_lm_batch
from repro.launch.sharding import DistConfig
from repro.launch.steps import make_decode_step, make_train_step
from repro.launch.train import make_lm_batch
from repro.models import ModelConfig, init_cache, init_params
from repro.optim import OptConfig, init_opt_state

LM_100M = ModelConfig(
    name="lm-100m", n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=2048, vocab_size=32000, rope_theta=1e4,
)   # ≈ 107M params

TINY = dataclasses.replace(LM_100M, name="lm-tiny", n_layers=2, d_model=128,
                           n_heads=4, n_kv_heads=2, d_ff=256,
                           vocab_size=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--chains", type=int, default=2)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    cfg = TINY if args.tiny else LM_100M
    chains = args.chains
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{chains} communication-free chains")

    dist = DistConfig(n_chains=chains, compute_dtype="float32",
                      use_pallas=False, remat=False)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=max(5, args.steps // 20),
                        total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, chains)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, dist, opt_cfg),
                      donate_argnums=(0, 1))
    manager = CheckpointManager(args.ckpt_dir, interval=50)

    for step in range(args.steps):
        batch = make_lm_batch(0, step, cfg, chains, args.batch, args.seq)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            loss = np.asarray(metrics["loss"])
            print(f"step {step:4d}  loss/chain {np.round(loss, 3)}")
        manager.maybe_save(step + 1, {"params": params, "opt": opt_state})

    # --- serving with the paper's ensemble combine (Eq. 7) ---
    decode = jax.jit(make_decode_step(cfg, dist, combine="simple"))
    cache = init_cache(cfg, chains, args.batch, max_len=32,
                       dtype=jnp.float32)
    toks = jnp.zeros((chains, args.batch, 1), jnp.int32)
    out = []
    for _ in range(8):
        logits, cache = decode(params, cache, {"tokens": toks})
        nxt = jnp.argmax(logits[:, :, -1:], axis=-1).astype(jnp.int32)
        toks = jnp.broadcast_to(nxt[None], (chains,) + nxt.shape).reshape(
            chains, args.batch, 1)
        out.append(int(np.asarray(nxt)[0]))
    print("ensemble-decoded tokens (batch 0):", out)


if __name__ == "__main__":
    main()
