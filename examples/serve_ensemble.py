"""Serve a model with the paper's prediction-combination rules at the token
level: per-chain next-token distributions are combined by Simple Average
(Eq. 7) or Weighted Average (Eq. 9, weights = inverse validation loss).

Also demonstrates straggler/failure handling at serving time: a chain that
misses its deadline is dropped from the combine by zeroing its weight.

  PYTHONPATH=src python examples/serve_ensemble.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.sharding import DistConfig
from repro.launch.steps import make_decode_step
from repro.models import ModelConfig, init_cache, init_params

CFG = ModelConfig(name="serve-demo", n_layers=2, d_model=128, n_heads=4,
                  n_kv_heads=2, d_ff=256, vocab_size=512, rope_theta=1e4)
CHAINS, BATCH = 4, 2

params = init_params(jax.random.PRNGKey(0), CFG, CHAINS)
dist = DistConfig(n_chains=CHAINS, compute_dtype="float32",
                  use_pallas=False)

# pretend validation losses per chain (would come from a held-out stream)
val_loss = jnp.array([2.31, 2.27, 2.40, 2.29])
weights = 1.0 / val_loss

decode_simple = jax.jit(make_decode_step(CFG, dist, combine="simple"))
decode_weighted = jax.jit(make_decode_step(CFG, dist, combine="weighted"))

cache = init_cache(CFG, CHAINS, BATCH, max_len=16, dtype=jnp.float32)
toks = jnp.ones((CHAINS, BATCH, 1), jnp.int32)

logits_s, cache2 = decode_simple(params, cache, {"tokens": toks})
logits_w, _ = decode_weighted(params, cache, {"tokens": toks,
                                              "chain_weights": weights})
print("simple-average  next-token logprob shape:", logits_s.shape)
print("weighted-average next-token logprob shape:", logits_w.shape)

# --- straggler cut: chain 2 misses its deadline → weight 0 ---
weights_cut = weights.at[2].set(0.0)
logits_cut, _ = decode_weighted(params, cache, {"tokens": toks,
                                                "chain_weights": weights_cut})
top_full = np.asarray(jnp.argmax(logits_w[0, 0]))
top_cut = np.asarray(jnp.argmax(logits_cut[0, 0]))
print(f"argmax token with all chains: {top_full}, "
      f"with chain 2 dropped: {top_cut} (service uninterrupted)")
